"""MoE layer: grouped-dispatch path vs one-hot oracle, routing invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.types import init_params
from repro.models.config import ModelConfig
from repro.models.moe import (
    _expert_ranks,
    moe_apply_dense,
    moe_apply_onehot,
    moe_spec,
    router_topk,
)


def _cfg(**kw):
    base = dict(
        name="moe-test", family="moe", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=2, d_ff=64, vocab_size=128, num_experts=4,
        experts_per_token=2, moe_d_ff=64,
    )
    base.update(kw)
    return ModelConfig(**base)


def _setup(cfg, B=2, S=16, seed=0):
    key = jax.random.PRNGKey(seed)
    params = init_params(moe_spec(cfg), key)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, S, cfg.d_model))
    return params, x


def test_grouped_matches_onehot_oracle():
    cfg = _cfg()
    params, x = _setup(cfg)
    # group_size >= N so grouping is trivial and capacities match exactly
    y1, l1 = moe_apply_dense(params, cfg, x, group_size=32)
    y2, l2 = moe_apply_onehot(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(l1["moe_aux"]), float(l2["moe_aux"]), rtol=1e-6)


def test_grouped_with_groups_still_finite_and_close():
    cfg = _cfg(num_experts=4, experts_per_token=1)
    params, x = _setup(cfg, B=4, S=16)
    y, losses = moe_apply_dense(params, cfg, x, group_size=16)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(losses["moe_aux"]) >= 1.0 - 1e-5  # aux >= 1 (E * sum(me*ce) >= 1)


def test_no_drop_when_capacity_generous():
    """With capacity >= g*k every token is processed; output is a weighted
    average of expert MLPs, so scaling x scales y in the linear regime."""
    cfg = _cfg(experts_per_token=1)
    params, x = _setup(cfg)
    y_lo, _ = moe_apply_dense(params, cfg, x, capacity_factor=8.0, group_size=32)
    # same routing, doubled capacity: identical result (nothing was dropped)
    y_hi, _ = moe_apply_dense(params, cfg, x, capacity_factor=16.0, group_size=32)
    np.testing.assert_allclose(np.asarray(y_lo), np.asarray(y_hi), rtol=1e-6)


def test_expert_ranks_unique_and_dense():
    """Per expert, ranks are exactly 0..count-1 (no gaps, no duplicates)."""
    rng = np.random.RandomState(0)
    flat_e = jnp.asarray(rng.randint(0, 7, size=64), jnp.int32)
    ranks = np.asarray(_expert_ranks(flat_e, 7))
    for e in range(7):
        r = np.sort(ranks[np.asarray(flat_e) == e])
        np.testing.assert_array_equal(r, np.arange(len(r)))


def test_router_topk_weights_normalized():
    cfg = _cfg(num_experts=8, experts_per_token=3)
    params, x = _setup(cfg)
    w, i, aux, z = router_topk(params, cfg, x.reshape(-1, cfg.d_model))
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert int(i.max()) < 8 and int(i.min()) >= 0
    assert float(aux) >= 1.0 - 1e-5  # load-balance lower bound at uniformity
    assert float(z) >= 0.0


def test_shared_expert_path():
    cfg = _cfg(num_shared_experts=1)
    params, x = _setup(cfg)
    y, _ = moe_apply_dense(params, cfg, x, group_size=32)
    # zero out shared expert -> output changes
    p2 = dict(params)
    p2["shared_wo"] = jnp.zeros_like(params["shared_wo"])
    y2, _ = moe_apply_dense(p2, cfg, x, group_size=32)
    assert not np.allclose(np.asarray(y), np.asarray(y2))


def test_grad_flows_through_dispatch():
    cfg = _cfg()
    params, x = _setup(cfg)

    def loss(p):
        y, aux = moe_apply_dense(p, cfg, x, group_size=32)
        return jnp.sum(y**2) + aux["moe_aux"]

    grads = jax.grad(loss)(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    # router receives gradient via combine weights and aux loss
    assert float(jnp.sum(jnp.abs(grads["router"]))) > 0


def _requires_partial_auto_shard_map():
    from repro.sharding.expert_parallel import HAS_PARTIAL_AUTO_SHARD_MAP

    return pytest.mark.skipif(
        not HAS_PARTIAL_AUTO_SHARD_MAP,
        reason="partial-auto shard_map needs jax.shard_map (jax >= 0.5)",
    )


@_requires_partial_auto_shard_map()
def test_expert_parallel_matches_dense_single_device():
    """shard_map all-to-all schedule == grouped-dispatch path (1-device mesh)."""
    from repro.sharding.expert_parallel import moe_apply_expert_parallel

    cfg = _cfg()
    params, x = _setup(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    y1, l1 = moe_apply_dense(params, cfg, x, capacity_factor=4.0, group_size=32)
    y2, l2 = moe_apply_expert_parallel(params, cfg, x, mesh=mesh,
                                       capacity_factor=4.0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(l1["moe_aux"]), float(l2["moe_aux"]), rtol=1e-5)


@_requires_partial_auto_shard_map()
def test_expert_parallel_with_shared_expert():
    from repro.sharding.expert_parallel import moe_apply_expert_parallel

    cfg = _cfg(num_shared_experts=1, experts_per_token=1)
    params, x = _setup(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    y, _ = moe_apply_expert_parallel(params, cfg, x, mesh=mesh)
    assert y.shape == x.shape and bool(jnp.all(jnp.isfinite(y)))
