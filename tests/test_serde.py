"""Checkpoint serde: the structured-layout round-trip fixes (lists/tuples,
None, empty containers, "/"-keys, extension dtypes), legacy wire-format
stability, numeric step selection, corrupt-archive recovery, and atomic
saves."""
import io
import json
import pathlib
import zipfile

import jax
import numpy as np
import pytest

from repro.checkpoint.serde import (params_from_bytes, params_to_bytes,
                                    restore_checkpoint, save_checkpoint)

try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - property tests just skip
    hypothesis = None

try:
    import ml_dtypes
except ImportError:  # pragma: no cover
    ml_dtypes = None


def _assert_same_tree(a, b):
    assert jax.tree_util.tree_structure(a) == jax.tree_util.tree_structure(b)
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        la, lb = np.asarray(la), np.asarray(lb)
        assert la.dtype == lb.dtype
        assert la.shape == lb.shape
        np.testing.assert_array_equal(la, lb)


def _roundtrip(tree):
    back = params_from_bytes(params_to_bytes(tree))
    _assert_same_tree(tree, back)
    return back


# -- the regression: list/tuple nodes must come back as lists/tuples ----------


def test_list_and_tuple_nodes_round_trip_exactly():
    """The old path-keyed layout silently rebuilt list/tuple nodes as dicts
    keyed by stringified indices; the stored treedef fixes that."""
    tree = {
        "layers": [
            {"w": np.ones((2, 3), np.float32)},
            {"w": np.zeros((3, 4), np.float32)},
        ],
        "opt": ("sgd", np.asarray(0.1, np.float32)),
    }
    back = _roundtrip(tree)
    assert isinstance(back["layers"], list)
    assert isinstance(back["opt"], tuple)


def test_opt_state_shaped_tree_round_trips():
    """The exact shape that bit the snapshot path: an sgd opt state whose
    momentum slot is an *empty tuple*."""
    tree = {
        "params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
        "opt_state": {"step": np.asarray(3, np.int32), "mom": ()},
    }
    back = _roundtrip(tree)
    assert back["opt_state"]["mom"] == ()


def test_none_empty_dict_and_scalars():
    tree = {
        "none": None,
        "empty": {},
        "scalar_f32": np.float32(1.5),
        "scalar_i32": np.asarray(7, np.int32),
    }
    back = _roundtrip(tree)
    assert back["none"] is None
    assert back["empty"] == {}
    assert np.asarray(back["scalar_f32"]).shape == ()


def test_bare_leaf_and_top_level_sequence_roots():
    _roundtrip(np.arange(5, dtype=np.float32))
    _roundtrip([np.ones(2, np.float32), (np.zeros(3, np.int32), None)])
    _roundtrip({})


def test_keys_containing_slashes_survive():
    """'/' is the legacy layout's path separator, so such keys must route
    through the structured layout instead of being split on restore."""
    tree = {"a/b": np.ones(3, np.float32), "c": {"d/e/f": np.zeros(2)}}
    _roundtrip(tree)


def test_reserved_spec_key_forces_structured_layout():
    """A plain-looking dict using the reserved ``__pytree__`` key would be
    misread as a structured archive if written legacy-style."""
    tree = {"__pytree__": np.ones(2, np.float32), "x": np.zeros(1)}
    _roundtrip(tree)


@pytest.mark.skipif(ml_dtypes is None, reason="ml_dtypes not installed")
def test_bfloat16_round_trips_with_dtype_preserved():
    tree = {"w": np.arange(6, dtype=ml_dtypes.bfloat16).reshape(2, 3),
            "b": np.ones(3, np.float32)}
    back = _roundtrip(tree)
    assert np.asarray(back["w"]).dtype == ml_dtypes.bfloat16


def test_mixed_dtypes_round_trip():
    tree = {"f32": np.linspace(0, 1, 4, dtype=np.float32),
            "i32": np.arange(4, dtype=np.int32),
            "f64": np.linspace(0, 1, 3),
            "u8": np.arange(3, dtype=np.uint8)}
    _roundtrip(tree)


# -- legacy wire format: plain trees keep their historical bytes ---------------


def test_plain_tree_keeps_legacy_path_keyed_layout():
    """Plain nested dicts are the vault wire format (content-hashed), so
    they must keep writing the exact legacy npz layout."""
    tree = {"layer": {"w": np.ones((2, 3), np.float32),
                      "b": np.zeros(3, np.float32)}}
    blob = params_to_bytes(tree)
    with zipfile.ZipFile(io.BytesIO(blob)) as zf:
        assert sorted(zf.namelist()) == ["layer/b.npy", "layer/w.npy"]
    # and byte-for-byte what a direct legacy savez would have produced
    # (jax flattens dict keys in sorted order, so 'b' precedes 'w')
    buf = io.BytesIO()
    np.savez(buf, **{"layer/b": tree["layer"]["b"], "layer/w": tree["layer"]["w"]})
    assert blob == buf.getvalue()
    _assert_same_tree(tree, params_from_bytes(blob))


def test_old_legacy_archives_still_readable():
    buf = io.BytesIO()
    np.savez(buf, **{"enc/w": np.ones((2, 2), np.float32),
                     "enc/b": np.zeros(2, np.float32),
                     "head": np.ones(4, np.float32)})
    back = params_from_bytes(buf.getvalue())
    assert set(back) == {"enc", "head"}
    assert set(back["enc"]) == {"w", "b"}


def test_serialization_is_deterministic():
    tree = {"a": [np.ones(2, np.float32), None], "b": (np.zeros(1),)}
    assert params_to_bytes(tree) == params_to_bytes(tree)


# -- checkpoint step selection + corruption recovery ---------------------------


def _save(tmp, step, val):
    return save_checkpoint(str(tmp), step,
                           {"w": np.full(2, float(val), np.float32)},
                           extra={"val": val})


def test_latest_is_numeric_not_lexicographic(tmp_path):
    """'ckpt_9.npz' > 'ckpt_00000010.npz' lexicographically; the resolver
    must still pick step 10."""
    _save(tmp_path, 10, 10)
    blob = params_to_bytes({"w": np.full(2, 9.0, np.float32)})
    (tmp_path / "ckpt_9.npz").write_bytes(blob)
    params, meta = restore_checkpoint(str(tmp_path))
    assert float(params["w"][0]) == 10.0
    assert meta["step"] == 10


def test_missing_step_names_requested_and_available(tmp_path):
    _save(tmp_path, 3, 3)
    _save(tmp_path, 7, 7)
    with pytest.raises(FileNotFoundError) as ei:
        restore_checkpoint(str(tmp_path), step=5)
    assert "step 5" in str(ei.value)
    assert "[3, 7]" in str(ei.value)


def test_empty_directory_raises_readably(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path))


def test_latest_skips_corrupt_archive(tmp_path):
    _save(tmp_path, 1, 1)
    _save(tmp_path, 2, 2)
    (tmp_path / "ckpt_00000002.npz").write_bytes(b"not an npz at all")
    params, meta = restore_checkpoint(str(tmp_path))
    assert meta["step"] == 1


def test_explicit_corrupt_step_raises_value_error(tmp_path):
    _save(tmp_path, 4, 4)
    (tmp_path / "ckpt_00000004.npz").write_bytes(b"\x00" * 16)
    with pytest.raises(ValueError, match="corrupt"):
        restore_checkpoint(str(tmp_path), step=4)


def test_all_corrupt_raises_with_skipped_list(tmp_path):
    _save(tmp_path, 1, 1)
    (tmp_path / "ckpt_00000001.npz").write_bytes(b"junk")
    with pytest.raises(FileNotFoundError, match="skipped corrupt"):
        restore_checkpoint(str(tmp_path))


def test_saves_are_atomic_and_leave_no_tmp_files(tmp_path):
    _save(tmp_path, 12, 12)
    names = sorted(p.name for p in tmp_path.iterdir())
    assert names == ["ckpt_00000012.json", "ckpt_00000012.npz"]
    params, meta = restore_checkpoint(str(tmp_path), step=12)
    assert meta == {"step": 12, "val": 12}


# -- hypothesis property tests -------------------------------------------------

if hypothesis is not None:
    _leaf = st.one_of(
        st.integers(1, 5).map(
            lambda n: np.linspace(-1, 1, n, dtype=np.float32)),
        st.integers(1, 4).map(lambda n: np.arange(n, dtype=np.int32)),
        st.just(np.float32(0.5)),  # 0-d scalar
        st.just(np.asarray(3, np.int32)),
    )
    _key = st.text(
        alphabet=st.characters(whitelist_categories=("Ll", "Nd"),
                               whitelist_characters="/_."),
        min_size=1, max_size=8)
    _node = st.recursive(
        st.one_of(_leaf, st.none()),
        lambda ch: st.one_of(
            st.dictionaries(_key, ch, max_size=3),
            st.lists(ch, max_size=3),
            st.lists(ch, max_size=3).map(tuple),
        ),
        max_leaves=10,
    )

    @given(tree=_node)
    @settings(max_examples=50, deadline=None)
    def test_any_tree_round_trips(tree):
        """Any mix of dicts (slashes allowed), lists, tuples, None, empty
        containers, and 0-d/1-d leaves of several dtypes round-trips with
        structure and dtypes intact."""
        _roundtrip(tree)

    if ml_dtypes is not None:
        _bf16 = st.integers(1, 6).map(
            lambda n: np.linspace(-2, 2, n).astype(ml_dtypes.bfloat16))

        @given(leaves=st.lists(_bf16, min_size=1, max_size=4))
        @settings(max_examples=20, deadline=None)
        def test_bf16_trees_round_trip(leaves):
            _roundtrip({"stack": leaves, "lone": leaves[0]})
