"""Durable continuum: full-world snapshot/restore (byte-identical resume,
per-subsystem state equality, archive integrity) and elastic membership
(admit/retire, region add/drain, conservation across every event)."""
import io
import json
import pathlib
import subprocess
import sys
import zipfile

import numpy as np
import pytest

from repro.core.continuum import Continuum
from repro.core.discovery import ModelQuery
from repro.core.incentives import OPERATOR, IncentiveLedger
from repro.core.vault import ModelCard
from repro.runtime.faults import FaultPlan
from repro.runtime.snapshot import (SnapshotError, restore_world,
                                    snapshot_manifest, snapshot_world)
from repro.runtime.topology import build_hierarchical_continuum
from repro.runtime.trace import (TraceRecording, build_drift_world,
                                 build_durable_world, durable_cycle_len,
                                 durable_verifier, run_drift_cycle,
                                 run_durable_cycle, schedule_drift_cycle,
                                 schedule_durable_cycle, serialize_trace)

GOLDEN = pathlib.Path(__file__).parent / "golden" / "durable_world.json"


def _fixture_plan():
    rec = TraceRecording.load(GOLDEN)
    return FaultPlan.from_dict(rec.plan), rec


def _run_cycles(cont, parties, cycles, clen, start=0):
    for c in range(start, cycles):
        schedule_durable_cycle(cont, cont.faults, parties, c, cycles, clen)
        run_durable_cycle(cont, c, clen)
    cont.loop.run_to_quiescence()
    cont.ledger.assert_conserved()
    return serialize_trace(cont.loop.log)


def _world_at_barrier(barrier, parties=12, cycles=3):
    """The fixture world run up to ``barrier`` cycles, ready to snapshot."""
    plan, rec = _fixture_plan()
    clen = durable_cycle_len(parties)
    cont = build_durable_world(plan)
    for c in range(barrier):
        schedule_durable_cycle(cont, plan, parties, c, cycles, clen)
        run_durable_cycle(cont, c, clen)
    return cont, rec, clen


# -- per-subsystem restore equality -------------------------------------------


def _restored_pair(barrier=1):
    cont, _rec, _clen = _world_at_barrier(barrier)
    back, _extra = restore_world(snapshot_world(cont),
                                 verifier=durable_verifier)
    return cont, back


def test_ledger_restores_identically():
    cont, back = _restored_pair()
    a, b = cont.ledger, back.ledger
    assert list(a.accounts) == list(b.accounts)  # insertion order too
    for name in a.accounts:
        assert a.accounts[name] == b.accounts[name]
    assert a.minted == b.minted
    assert a.flagged == b.flagged
    assert a.operators == b.operators
    b.assert_conserved()


def test_vaults_restore_byte_identically():
    cont, back = _restored_pair()
    assert sorted(cont.edges) == sorted(back.edges)
    for sid, edge in cont.edges.items():
        for ea, eb in zip(edge.vault.entries(), back.edges[sid].vault.entries()):
            assert ea.card.to_json() == eb.card.to_json()
            assert ea.blob == eb.blob  # byte-identical => same content hash
            assert ea.signature == eb.signature
            # integrity machinery still live on the restored vault
            back.edges[sid].vault.fetch(ea.card.model_id)


def test_discovery_restores_identically():
    cont, back = _restored_pair()
    a = [(c.to_json(), v) for c, v in cont.discovery.entries()]
    b = [(c.to_json(), v) for c, v in back.discovery.entries()]
    assert a == b
    assert cont.discovery.stats == back.discovery.stats
    q = ModelQuery(task="durable", min_accuracy=0.0)
    assert ([r.card.model_id for r in cont.discovery.query(q, top_k=5)]
            == [r.card.model_id for r in back.discovery.query(q, top_k=5)])


def test_frontier_restores_with_original_seq_numbers():
    cont, back = _restored_pair()
    fa, fb = cont.loop.frontier(), back.loop.frontier()
    assert fa and fa == fb  # membership events pending at the barrier
    assert cont.loop.next_seq == back.loop.next_seq
    assert cont.loop.events_processed == back.loop.events_processed
    assert cont.clock.now() == back.clock.now()


def test_topology_and_counters_restore_identically():
    cont, back = _restored_pair()
    ta, tb = cont.topology, back.topology
    assert sorted(ta.regions) == sorted(tb.regions)
    for rid in ta.regions:
        ra, rb = ta.regions[rid], tb.regions[rid]
        assert ra.stats == rb.stats
        assert sorted(ra.edge_ids) == sorted(rb.edge_ids)
    assert cont.denied_fetches == back.denied_fetches
    assert cont.membership_refusals == back.membership_refusals
    assert cont.members == back.members
    assert cont.retired == back.retired
    assert cont.fault_stats == back.fault_stats
    assert cont.traffic == back.traffic


# -- byte-identical resume vs the golden fixture -------------------------------


@pytest.mark.parametrize("barrier", [1, 2])
def test_snapshot_restore_continue_matches_golden(barrier):
    """Snapshot at a cycle barrier, restore into a fresh continuum, finish
    the run: pre + post trace must equal the checked-in golden fixture."""
    cont, rec, clen = _world_at_barrier(barrier)
    pre = serialize_trace(cont.loop.log)
    snap = snapshot_world(cont, extra={"next_cycle": barrier})
    del cont

    back, extra = restore_world(snap, verifier=durable_verifier)
    assert extra == {"next_cycle": barrier}
    post = _run_cycles(back, 12, 3, clen, start=barrier)
    assert (pre + post) == rec.trace.encode()


def test_restore_survives_process_death(tmp_path):
    """The acceptance path: record + snapshot in one process, let it die,
    restore and continue in another — concatenation is byte-identical."""
    plan, rec = _fixture_plan()
    clen = durable_cycle_len(12)
    env_script = """
import sys
sys.path.insert(0, {src!r})
from repro.runtime.faults import FaultPlan
from repro.runtime.snapshot import restore_world, snapshot_world
from repro.runtime.trace import (build_durable_world, durable_cycle_len,
                                 durable_verifier, run_durable_cycle,
                                 schedule_durable_cycle, serialize_trace)
plan = FaultPlan.from_dict({plan!r})
clen = durable_cycle_len(12)
"""
    src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    header = env_script.format(src=src, plan=plan.to_dict())
    phase1 = header + f"""
cont = build_durable_world(plan)
schedule_durable_cycle(cont, plan, 12, 0, 3, clen)
run_durable_cycle(cont, 0, clen)
open({str(tmp_path / "pre.trace")!r}, "wb").write(
    serialize_trace(cont.loop.log))
open({str(tmp_path / "world.snap")!r}, "wb").write(snapshot_world(cont))
sys.exit(0)  # process dies with the world in memory
"""
    phase2 = header + f"""
data = open({str(tmp_path / "world.snap")!r}, "rb").read()
cont, _ = restore_world(data, verifier=durable_verifier)
for c in range(1, 3):
    schedule_durable_cycle(cont, plan, 12, c, 3, clen)
    run_durable_cycle(cont, c, clen)
cont.loop.run_to_quiescence()
cont.ledger.assert_conserved()
open({str(tmp_path / "post.trace")!r}, "wb").write(
    serialize_trace(cont.loop.log))
"""
    for script in (phase1, phase2):
        proc = subprocess.run([sys.executable, "-c", script],
                              capture_output=True, text=True)
        assert proc.returncode == 0, proc.stderr
    pre = (tmp_path / "pre.trace").read_bytes()
    post = (tmp_path / "post.trace").read_bytes()
    assert (pre + post) == rec.trace.encode()


# -- archive integrity ---------------------------------------------------------


def test_snapshot_refuses_non_durable_frontier_events():
    cont, _rec, _clen = _world_at_barrier(1)
    cont.loop.call_after(5.0, lambda now: None, label="ephemeral closure")
    with pytest.raises(SnapshotError, match="durable"):
        snapshot_world(cont)


def test_tampered_archive_is_rejected():
    cont, _rec, _clen = _world_at_barrier(1)
    snap = bytearray(snapshot_world(cont))
    snap[len(snap) // 2] ^= 0x01
    with pytest.raises(SnapshotError):
        restore_world(bytes(snap), verifier=durable_verifier)


def test_snapshot_manifest_is_inspectable():
    cont, _rec, _clen = _world_at_barrier(1)
    m = snapshot_manifest(snapshot_world(cont, extra={"tag": "b1"}))
    assert m["version"] == 1
    assert m["extra"] == {"tag": "b1"}
    assert m["clock"]["now"] == cont.clock.now()
    assert len(m["frontier"]) == len(cont.loop.frontier())


def test_snapshot_bytes_are_deterministic():
    a1, _rec, _clen = _world_at_barrier(1)
    a2, _rec, _clen = _world_at_barrier(1)
    assert snapshot_world(a1) == snapshot_world(a2)


# -- elastic membership --------------------------------------------------------


def _micro_world(plan=None):
    cont = build_hierarchical_continuum(3, 2, ledger=IncentiveLedger(),
                                        faults=plan or FaultPlan(seed=0))
    return cont


def _publish(cont, pid, acc=0.8, mid=None):
    card = ModelCard(model_id=mid or f"{pid}/m", task="t", arch="toy",
                     owner=pid, num_params=3,
                     metrics={"accuracy": acc, "per_class": {}})
    return cont.publish(pid, {"w": np.ones(3, np.float32)}, card)


def test_admit_opens_account_and_mints_conservingly():
    cont = _micro_world()
    cont.admit_party("alice")
    cont.loop.run_to_quiescence()
    assert "alice" in cont.members
    assert cont.ledger.balance("alice") > 0
    cont.ledger.assert_conserved()


def test_retire_escrows_deregisters_and_gates():
    cont = _micro_world()
    _publish(cont, "bob")
    _publish(cont, "carol")
    region_op = cont.topology.region_of("bob").operator
    bob_before = cont.ledger.balance("bob")
    op_before = cont.ledger.balance(region_op)
    minted = cont.ledger.minted

    cont.retire_party("bob")
    cont.loop.run_to_quiescence()
    assert "bob" in cont.retired
    # balance escrowed to the home region operator, nothing minted
    assert cont.ledger.balance("bob") == 0.0
    assert cont.ledger.balance(region_op) == pytest.approx(
        op_before + bob_before)
    assert cont.ledger.minted == minted
    cont.ledger.assert_conserved()
    # cards gone from the cloud index and every region shard
    q = ModelQuery(task="t", min_accuracy=0.0)
    assert all(r.card.owner != "bob" for r in cont.discovery.query(q))
    for rid in cont.topology.regions:
        shard = cont.topology.regions[rid].shard
        assert all(r.card.owner != "bob" for r in shard.query(q))
    # both planes refuse retired parties, on a dedicated counter
    denied_before = cont.denied_fetches
    _publish(cont, "bob", mid="bob/m2")
    cont.discover_and_fetch(ModelQuery(task="t", min_accuracy=0.0),
                            requester="bob")
    assert cont.membership_refusals == 2
    assert cont.denied_fetches == denied_before
    cont.ledger.assert_conserved()


def test_readmission_of_retired_party_is_refused():
    cont = _micro_world()
    cont.retire_party("bob")
    cont.loop.run_to_quiescence()
    with pytest.raises(ValueError, match="re-admission"):
        cont.admit_party("bob")


def test_add_region_wires_operator_edges_and_placement():
    cont = _micro_world()
    before = set(cont.edges)
    cont.add_region("rgx00", n_edges=2)
    cont.loop.run_to_quiescence()
    assert "rgx00" in cont.topology.regions
    assert "region:rgx00" in cont.ledger.operators
    new_edges = set(cont.edges) - before
    assert new_edges == {"edge:rgx00:00", "edge:rgx00:01"}
    # the new region is a live placement target: some party homes there
    homed = [f"p{i:03d}" for i in range(64)
             if cont.topology.region_of(f"p{i:03d}").region_id == "rgx00"]
    assert homed
    _publish(cont, homed[0])
    assert cont.nearest_edge(homed[0]).server_id in new_edges
    cont.ledger.assert_conserved()


def test_drain_region_migrates_models_and_escrows_operator():
    cont = _micro_world()
    cont.add_region("rgx00", n_edges=1)
    cont.loop.run_to_quiescence()
    homed = next(f"p{i:03d}" for i in range(64)
                 if cont.topology.region_of(f"p{i:03d}").region_id == "rgx00")
    stored = _publish(cont, homed)
    vault_of = {c.model_id: v for c, v in cont.discovery.entries()}
    assert vault_of[stored.model_id].startswith("edge:rgx00:")
    cloud_before = cont.ledger.balance(OPERATOR)
    op_balance = cont.ledger.balance("region:rgx00")

    cont.drain_region("rgx00")
    cont.loop.run_to_quiescence()
    assert "rgx00" not in cont.topology.regions
    assert not any(sid.startswith("edge:rgx00:") for sid in cont.edges)
    # the dead operator's balance escrowed to the cloud operator
    assert cont.ledger.balance("region:rgx00") == 0.0
    assert cont.ledger.balance(OPERATOR) == pytest.approx(
        cloud_before + op_balance)
    cont.ledger.assert_conserved()
    # the model migrated to the owner's new home edge and is still served
    params, card, _r = cont.discover_and_fetch(
        ModelQuery(task="t", min_accuracy=0.0), requester="zz-requester")
    assert card.model_id == stored.model_id
    np.testing.assert_array_equal(params["w"], np.ones(3, np.float32))
    cont.ledger.assert_conserved()


def test_drain_refuses_last_region_at_fire_time():
    cont = _micro_world()
    for rid in ["rg001", "rg002"]:
        cont.drain_region(rid)
        cont.loop.run_to_quiescence()
    cont.drain_region("rg000")
    with pytest.raises(ValueError, match="last"):
        cont.loop.run_to_quiescence()


def test_membership_survives_snapshot_mid_flight():
    """Pending admit/retire events snapshot as durable payloads and fire
    identically after restore; conservation holds after each one."""
    cont = _micro_world()
    _publish(cont, "bob")
    cont.admit_party("newbie", delay=10.0)
    cont.retire_party("bob", delay=20.0)
    snap = snapshot_world(cont)
    back, _ = restore_world(snap)
    back.loop.run_to_quiescence()
    back.ledger.assert_conserved()
    assert "newbie" in back.members
    assert "bob" in back.retired
    assert back.ledger.balance("bob") == 0.0


# -- cohort (device-resident) state -------------------------------------------


def test_cohort_state_restores_bit_identically():
    from repro.models.small import make_lr
    from repro.runtime.population import PartyPopulation

    def _pop():
        rng = np.random.default_rng(3)
        x = rng.normal(size=(4, 32, 6)).astype(np.float32)
        y = rng.integers(0, 3, size=(4, 32)).astype(np.int32)
        return PartyPopulation(make_lr(num_features=6, num_classes=3),
                               x, y, task="t", lr=0.1, batch_size=8, seed=1)

    pop = _pop()
    pop.train_epochs(1)
    cont = Continuum(ledger=IncentiveLedger())
    cont.add_edge_server("e0")
    snap = snapshot_world(cont, cohorts=[pop])

    fresh = _pop()  # same construction, pre-training state
    back, _ = restore_world(snap, cohorts=[fresh])
    for a, b in zip(np.asarray(pop.state.params["w"]).ravel(),
                    np.asarray(fresh.state.params["w"]).ravel()):
        assert a == b
    # the continuation must be bit-identical, incl. the RNG-driven schedule
    la = pop.train_epochs(2)
    lb = fresh.train_epochs(2)
    assert la == lb
    for pa, pb in zip(pop.all_party_params(), fresh.all_party_params()):
        for leaf_a, leaf_b in zip(pa.values(), pb.values()):
            np.testing.assert_array_equal(leaf_a, leaf_b)


def test_cohort_count_mismatch_is_rejected():
    cont = Continuum(ledger=IncentiveLedger())
    cont.add_edge_server("e0")
    snap = snapshot_world(cont)
    with pytest.raises(SnapshotError, match="cohort"):
        restore_world(snap, cohorts=[object()])


# -- serving tier durability ---------------------------------------------------


def _serving_world():
    """A deterministic 2-region serving world with traffic in flight.

    Publishes are synchronous (no pending closures) and every serving
    event carries a durable payload, so the world is snapshottable at any
    instant — including mid-overload."""
    from repro.runtime.serving import (PredictRequest, ServingConfig,
                                       ServingTier)

    cont = build_hierarchical_continuum(2, 2, ledger=IncentiveLedger(),
                                        faults=FaultPlan(seed=7))
    for i in range(4):
        _publish(cont, f"pub{i}", acc=0.6 + 0.05 * i)
    tier = ServingTier(cont, ServingConfig(
        placement_every_s=5.0, hot_threshold=3, decay_windows=3,
        max_wait_s=0.5, max_batch=2, max_queue_depth=2,
        max_slots_per_key=1))
    for k in range(24):
        tier.submit(PredictRequest(
            request_id=f"r{k:03d}", requester=f"pub{k % 4}", task="t",
            prompt_tokens=4 + (k % 3) * 8, max_new_tokens=4,
            at=1.0 + 0.3 * k, tier=k % 3))
    return cont, tier


def test_serving_snapshot_midflight_resumes_byte_identically():
    """Snapshot a serving world mid-traffic (queued requests, armed slot
    timers, pending reviews), restore, run dry: pre + post must equal the
    uninterrupted run's trace byte for byte."""
    ref, _tier = _serving_world()
    ref.loop.run_to_quiescence()
    ref_trace = serialize_trace(ref.loop.log)

    cont, _tier = _serving_world()
    cont.loop.run_until(4.0)  # mid-wave: the request plane is busy
    frontier = cont.loop.frontier()
    assert any(p.get("durable") == "serving" for _t, _s, _l, p in frontier)
    pre = serialize_trace(cont.loop.log)
    snap = snapshot_world(cont)
    del cont

    back, _ = restore_world(snap)
    assert back.serving is not None
    back.loop.run_to_quiescence()
    back.ledger.assert_conserved()
    assert pre + serialize_trace(back.loop.log) == ref_trace


def test_serving_state_restores_identically():
    import dataclasses as dc

    cont, tier = _serving_world()
    cont.loop.run_until(4.0)
    back, _ = restore_world(snapshot_world(cont))
    bt = back.serving
    assert bt.requests == tier.requests
    assert bt._latencies == tier._latencies
    assert (bt._review_armed, bt._activity) == (tier._review_armed,
                                                tier._activity)
    for sid, server in tier.servers.items():
        bs = bt.servers[sid]
        assert dc.asdict(bs.stats) == dc.asdict(server.stats)
        assert bs.window_hits == server.window_hits
        assert bs.queue.pending() == server.queue.pending()
        assert sorted(bs._timers) == sorted(server._timers)
        assert bs._inflight == server._inflight
        assert sorted(c.model_id for c in bs.replicas.cards()) == \
            sorted(c.model_id for c in server.replicas.cards())


def test_serving_restore_rebinds_on_complete():
    """In-flight requests lost their per-request callbacks with the dead
    process; after restore they report through serving_on_complete."""
    from repro.core.continuum import Outcome

    cont, _tier = _serving_world()
    cont.loop.run_until(4.0)
    snap = snapshot_world(cont)
    outs = []
    back, _ = restore_world(snap, serving_on_complete=outs.append)
    back.loop.run_to_quiescence()
    assert outs and all(isinstance(o, Outcome) for o in outs)
    assert any(o.ok for o in outs)


# -- scenario dynamics (drift) across snapshots -------------------------------

DRIFT_GOLDEN = pathlib.Path(__file__).parent / "golden" / "drift_microworld.json"


def _drift_world_at_barrier(barrier, parties=12, cycles=3):
    """The drift fixture's world run to ``barrier``, scenario events pending."""
    rec = TraceRecording.load(DRIFT_GOLDEN)
    plan = FaultPlan.from_dict(dict(rec.plan))
    clen = durable_cycle_len(parties)
    cont = build_drift_world(plan)
    for c in range(barrier):
        schedule_drift_cycle(cont, plan, parties, c, cycles, clen)
        run_drift_cycle(cont, c, clen)
    return cont, rec, clen


@pytest.mark.parametrize("barrier", [1, 2])
def test_mid_drift_snapshot_restores_and_continues_byte_identically(barrier):
    """A world snapshotted *mid-drift* — concept-drift (and, at barrier 2,
    task-retirement) events pending on the frontier — restores in a fresh
    continuum and finishes the run byte-identically to the golden trace."""
    cont, rec, clen = _drift_world_at_barrier(barrier)
    assert any(p is not None and p.get("durable") == "scenario"
               for _t, _n, _l, p in cont.loop.frontier())
    pre = serialize_trace(cont.loop.log)
    snap = snapshot_world(cont)
    del cont

    back, _extra = restore_world(snap, verifier=durable_verifier)
    assert back.scenario is not None  # engine auto-reattached
    for c in range(barrier, 3):
        schedule_drift_cycle(back, FaultPlan.from_dict(dict(rec.plan)), 12,
                             c, 3, clen)
        run_drift_cycle(back, c, clen)
    back.loop.run_to_quiescence()
    back.ledger.assert_conserved()
    post = serialize_trace(back.loop.log)
    assert (pre + post) == rec.trace.encode()


def test_scenario_state_restores_identically():
    """Engine stats, staleness penalties, demotions, and the retired-task
    set all travel in the archive."""
    cont, _rec, _clen = _drift_world_at_barrier(2)
    back, _ = restore_world(snapshot_world(cont), verifier=durable_verifier)
    assert back.scenario.stats == cont.scenario.stats
    assert back.retired_tasks == cont.retired_tasks
    assert back.task_refusals == cont.task_refusals
    assert back.discovery._stale == cont.discovery._stale
    for rid in cont.topology.regions:
        assert (back.topology.regions[rid].shard._stale
                == cont.topology.regions[rid].shard._stale)
    assert back.ledger.demoted == cont.ledger.demoted
    # the drift already fired by barrier 2 left visible staleness
    assert cont.scenario.stats["drifts"] == 1
    assert cont.discovery._stale
