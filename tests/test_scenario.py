"""Scenario dynamics: concept drift, staleness-aware discovery, task
lifecycle, and the drift_microworld golden trace.

The claims under test are the paper's non-stationary ones: when the data
a task's models were trained on drifts, the cards indexed for that task
must *lose* discovery rank against fresh models (staleness-decayed index
scoring), their demoted owners must stop minting publish rewards without
breaking ledger conservation, and a retired task must refuse publishes
and miss queries — all as durable, replayable events.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core.continuum import Continuum, OutcomeStatus
from repro.core.discovery import ModelQuery
from repro.core.incentives import IncentiveLedger
from repro.core.vault import ModelCard
from repro.runtime.faults import FaultPlan
from repro.runtime.scenario import (ScenarioEngine, apply_concept_drift,
                                    build_federated_cohorts,
                                    federated_party_shards, label_shift_map)
from repro.runtime.topology import build_hierarchical_continuum
from repro.runtime.trace import (TraceRecording, assert_replay, record,
                                 trace_digest)

GOLDEN = pathlib.Path(__file__).parent / "golden" / "drift_microworld.json"


def _flat_world():
    cont = Continuum(ledger=IncentiveLedger(), faults=FaultPlan(seed=0))
    for e in range(2):
        cont.add_edge_server(f"edge{e:02d}")
    ScenarioEngine(cont)
    return cont


def _hier_world():
    cont = build_hierarchical_continuum(3, 2, ledger=IncentiveLedger(),
                                        faults=FaultPlan(seed=0))
    ScenarioEngine(cont)
    return cont


def _publish(cont, pid, acc=0.8, mid=None, task="t"):
    card = ModelCard(model_id=mid or f"{pid}/m", task=task, arch="toy",
                     owner=pid, num_params=3,
                     metrics={"accuracy": acc, "per_class": {}})
    return cont.publish(pid, {"w": np.ones(3, np.float32)}, card)


# -- golden trace -------------------------------------------------------------

def test_golden_drift_trace_replays_byte_identical():
    """The checked-in drift trace pins the whole scenario layer: drift
    scheduling, restaling, demotion, task retirement refusals, and the
    fee/refund bookkeeping around them.  Any behavioural change shows up
    as a byte diff against the fixture."""
    rec = TraceRecording.load(GOLDEN)
    assert rec.digest == trace_digest(rec.trace.encode())
    ops = {json.loads(line)["p"]["op"]
           for line in rec.trace.splitlines()
           if json.loads(line)["p"] is not None}
    # the fixture exercises the scenario plane, both task markets, and
    # the retired-task publish gate
    assert {"drift", "retire_task", "publish", "fetch",
            "publish_task_retired"} <= ops
    assert_replay(rec)


def test_drift_microworld_end_state_under_fixture_plan():
    """Under the golden plan the microworld demonstrably restales, demotes,
    and refuses: stale cards carry penalties, demoted owners are gated,
    publishes into the retired task were refused — with conservation."""
    from repro.runtime.trace import (build_drift_world, durable_cycle_len,
                                     run_drift_cycle, schedule_drift_cycle)

    rec = TraceRecording.load(GOLDEN)
    plan = FaultPlan.from_dict(dict(rec.plan))
    clen = durable_cycle_len(12)
    cont = build_drift_world(plan)
    for c in range(3):
        schedule_drift_cycle(cont, plan, 12, c, 3, clen)
        if c == 2:
            # just past the final boundary drift (t = 2*clen + 0.3) but
            # before cycle-2 republishes: the re-ranked cards carry live
            # staleness penalties (a fresh register() clears its own)
            cont.loop.run_until(c * clen + 0.5)
            assert cont.discovery._stale
        run_drift_cycle(cont, c, clen)
    cont.loop.run_to_quiescence()
    stats = cont.scenario.stats
    assert stats["drifts"] == 2 and stats["retired_tasks"] == 1
    assert stats["restaled"] > 0
    assert stats["demoted"] == len(cont.ledger.demoted) > 0
    assert cont.task_refusals > 0
    # every demoted owner's models stopped minting but kept publishing
    for owner in cont.ledger.demoted:
        assert cont.ledger.accounts[owner].published > 0
    cont.ledger.assert_conserved()


def test_drift_microworld_rerecords_identically():
    """Recording the scenario twice from the same plan is byte-stable
    (no hidden wall-clock or RNG state in the scenario layer)."""
    plan = FaultPlan(seed=3, drop_prob=0.05, churn=0.1)
    a = record("drift_microworld", plan, parties=8, cycles=3)
    b = record("drift_microworld", plan, parties=8, cycles=3)
    assert a.digest == b.digest


# -- staleness-aware discovery ------------------------------------------------

def test_stale_card_loses_rank_to_equally_accurate_fresh_card():
    """After drift, a restaled card must rank *below* a fresh card with
    the same listed accuracy — the staleness penalty, not just the decayed
    accuracy, demotes it."""
    cont = _flat_world()
    _publish(cont, "alice", acc=0.8, mid="m_stale")
    cont.loop.run_to_quiescence()

    cont.scenario.schedule_drift("t", severity=0.5, delay=1.0)
    cont.loop.run_to_quiescence()
    # stale card now listed at 0.4 with a 0.5 penalty
    listed = {c.model_id: c.metrics["accuracy"]
              for c, _ in cont.discovery.entries()}
    assert listed["m_stale"] == pytest.approx(0.4)

    _publish(cont, "bob", acc=0.4, mid="m_fresh")
    cont.loop.run_to_quiescence()
    res = cont.discovery.query(ModelQuery(task="t"), top_k=2)
    assert [r.card.model_id for r in res] == ["m_fresh", "m_stale"]
    assert res[0].score > res[1].score


def test_fresh_republish_clears_staleness_penalty():
    """Republishing a new version is a fresh measurement: the penalty is
    cleared and the model competes on its new accuracy alone."""
    cont = _flat_world()
    _publish(cont, "alice", acc=0.8, mid="m1")
    cont.loop.run_to_quiescence()
    cont.scenario.schedule_drift("t", severity=0.5, delay=1.0)
    cont.loop.run_to_quiescence()
    assert cont.discovery._stale["m1"] == pytest.approx(0.5)

    _publish(cont, "alice", acc=0.7, mid="m1")  # version 2: retrained
    cont.loop.run_to_quiescence()
    assert "m1" not in cont.discovery._stale
    res = cont.discovery.query(ModelQuery(task="t"), top_k=1)
    assert res[0].card.metrics["accuracy"] == pytest.approx(0.7)


def test_staleness_accumulates_across_drifts():
    cont = _flat_world()
    _publish(cont, "alice", acc=0.9, mid="m1")
    cont.loop.run_to_quiescence()
    cont.scenario.schedule_drift("t", severity=0.2, delay=1.0)
    cont.scenario.schedule_drift("t", severity=0.1, delay=2.0)
    cont.loop.run_to_quiescence()
    assert cont.discovery._stale["m1"] == pytest.approx(0.3)
    # accuracy decayed multiplicatively through both events
    (card, _vid), = cont.discovery.entries()
    assert card.metrics["accuracy"] == pytest.approx(0.9 * 0.8 * 0.9)


def test_region_shards_restale_with_the_cloud_index():
    """Drift must demote stale cards in *region-local* ranking too, or
    region-first discovery would keep serving them."""
    cont = _hier_world()
    pid = "edge:rg000:00"
    _publish(cont, pid, acc=0.8, mid="m1")
    cont.loop.run_to_quiescence()
    shard = cont.topology.regions["rg000"].shard
    assert shard.entries()[0][0].metrics["accuracy"] == pytest.approx(0.8)
    cont.scenario.schedule_drift("t", severity=0.5, delay=1.0)
    cont.loop.run_to_quiescence()
    assert shard.entries()[0][0].metrics["accuracy"] == pytest.approx(0.4)
    assert shard._stale["m1"] == pytest.approx(0.5)


# -- demotion gates minting ---------------------------------------------------

def test_drift_demotes_owners_below_threshold_and_gates_minting():
    cont = _flat_world()
    _publish(cont, "alice", acc=0.4, mid="mA")   # decays to 0.2 < 0.3
    _publish(cont, "bob", acc=0.9, mid="mB")     # decays to 0.45 >= 0.3
    cont.loop.run_to_quiescence()
    cont.scenario.schedule_drift("t", severity=0.5, delay=1.0,
                                 demote_below=0.3)
    cont.loop.run_to_quiescence()
    assert cont.ledger.demoted == {"alice"}
    assert cont.scenario.stats["demoted"] == 1

    minted_before = cont.ledger.minted
    published_before = cont.ledger.accounts["alice"].published
    _publish(cont, "alice", acc=0.95, mid="mA2")
    cont.loop.run_to_quiescence()
    # the publish landed (counted, indexed) but minted nothing
    assert cont.ledger.accounts["alice"].published == published_before + 1
    assert cont.ledger.minted == minted_before
    cont.ledger.assert_conserved()

    # promotion lifts the gate: the next publish mints again
    cont.ledger.promote("alice")
    _publish(cont, "alice", acc=0.95, mid="mA3")
    cont.loop.run_to_quiescence()
    assert cont.ledger.minted > minted_before
    cont.ledger.assert_conserved()


def test_demotion_is_not_a_flag_and_conserves():
    """Demotion must not burn, escrow, or flag — distribution() accounting
    and conservation stay intact."""
    ledger = IncentiveLedger()
    ledger.on_publish("p1", accuracy=0.9)
    minted, balance = ledger.minted, ledger.balance("p1")
    ledger.demote("p1")
    assert "p1" not in ledger.flagged
    assert (ledger.minted, ledger.balance("p1")) == (minted, balance)
    assert ledger.distribution()["demoted"] == 1
    ledger.assert_conserved()
    ledger.promote("p1")
    assert ledger.distribution()["demoted"] == 0


# -- task lifecycle -----------------------------------------------------------

def test_retired_task_refuses_publishes_and_misses_queries():
    cont = _flat_world()
    _publish(cont, "alice", acc=0.8, mid="m1")
    cont.loop.run_to_quiescence()
    cont.scenario.schedule_task_retirement("t", delay=1.0)
    cont.loop.run_to_quiescence()
    assert "t" in cont.retired_tasks
    assert cont.discovery.entries() == []

    outcomes = []
    card = ModelCard(model_id="m2", task="t", arch="toy", owner="bob",
                     num_params=3, metrics={"accuracy": 0.9, "per_class": {}})
    cont.publish_async("bob", {"w": np.ones(3, np.float32)}, card,
                       on_complete=outcomes.append)
    cont.discover_and_fetch_async(ModelQuery(task="t"), requester="carol",
                                  on_complete=outcomes.append)
    cont.loop.run_to_quiescence()
    statuses = {o.status for o in outcomes}
    assert statuses == {OutcomeStatus.REFUSED, OutcomeStatus.MISS}
    refused, = [o for o in outcomes if o.status is OutcomeStatus.REFUSED]
    assert refused.reason == "task_retired"
    assert cont.task_refusals == 1
    # the refused publish earned bob nothing (the publish never landed)
    assert ("bob" not in cont.ledger.accounts
            or cont.ledger.accounts["bob"].mint_earned == 0.0)
    cont.ledger.assert_conserved()

    # arrival re-opens the task: publishes land and mint again
    cont.scenario.schedule_task_arrival("t", delay=1.0)
    cont.loop.run_to_quiescence()
    assert "t" not in cont.retired_tasks
    _publish(cont, "bob", acc=0.9, mid="m2")
    cont.loop.run_to_quiescence()
    assert cont.ledger.accounts["bob"].mint_earned > 0.0
    cont.ledger.assert_conserved()


def test_retire_task_empties_region_shards_too():
    cont = _hier_world()
    _publish(cont, "edge:rg000:00", acc=0.8, mid="m1")
    _publish(cont, "edge:rg001:00", acc=0.7, mid="m2", task="other")
    cont.loop.run_to_quiescence()
    cont.scenario.schedule_task_retirement("t", delay=1.0)
    cont.loop.run_to_quiescence()
    for rid in cont.topology.regions:
        shard = cont.topology.regions[rid].shard
        assert all(c.task != "t" for c, _ in shard.entries())
    # the other task is untouched
    assert [c.model_id for c, _ in cont.discovery.entries()] == ["m2"]


def test_scenario_engine_rejects_unknown_op():
    cont = _flat_world()
    with pytest.raises(ValueError):
        cont.scenario.handle({"op": "meteor_strike", "durable": "scenario"})


# -- concept drift over real federated cohorts --------------------------------

def test_label_shift_map_is_a_seeded_permutation():
    m = label_shift_map(10, severity=0.5, seed=4)
    assert sorted(m) == list(range(10))          # a permutation
    assert not np.array_equal(m, np.arange(10))  # that actually moves labels
    assert np.array_equal(m, label_shift_map(10, severity=0.5, seed=4))
    assert not np.array_equal(m, label_shift_map(10, severity=0.5, seed=5))
    # full severity moves (almost) everything, zero severity still moves 2
    assert (label_shift_map(10, 0.0, seed=0) != np.arange(10)).sum() == 2


def test_apply_concept_drift_shifts_cohorts_and_eval_in_place():
    from repro.data.federated_datasets import make_lr_synthetic

    ds = make_lr_synthetic(num_clients=6, num_features=12, num_classes=5,
                           seed=0, min_samples=30, max_samples=60)
    cohorts, ex, ey = build_federated_cohorts(ds, 4, samples_per_party=24,
                                              seed=0)
    ey_ref = ey  # the reference exchange actors / verifiers would hold
    y0 = [pop.y.copy() for pop in cohorts]
    mapping = label_shift_map(5, severity=1.0, seed=1)
    drifted = apply_concept_drift(cohorts, ey, mapping)
    assert drifted == 4
    for pop, before in zip(cohorts, y0):
        assert np.array_equal(pop.y, mapping[before])
        # device copy refreshed: evaluate() consumes the drifted labels
        acc_dev = pop.evaluate(ex, ey)
        assert acc_dev.shape == (pop.num_parties,)
    # eval shifted through the SAME array object (in-place)
    assert ey_ref is ey and np.array_equal(ey_ref, ey)


def test_federated_shards_are_rectangular_deterministic_and_skewed():
    from repro.data.federated_datasets import make_lr_synthetic

    ds = make_lr_synthetic(num_clients=8, num_features=10, num_classes=6,
                           seed=0, min_samples=40, max_samples=80)
    x1, y1 = federated_party_shards(ds, 5, alpha=0.1, samples_per_party=32,
                                    seed=3)
    x2, y2 = federated_party_shards(ds, 5, alpha=0.1, samples_per_party=32,
                                    seed=3)
    assert x1.shape == (5, 32, 10) and y1.shape == (5, 32)
    assert np.array_equal(x1, x2) and np.array_equal(y1, y2)
    # low alpha = strong label skew: parties' class mixes differ sharply
    hists = np.stack([np.bincount(y, minlength=6) for y in y1])
    assert (hists.max(axis=1) > 0.5 * y1.shape[1]).any()
