"""FL substrate: aggregation, selection, heterogeneity, full FL rounds."""
import jax
import numpy as np
import pytest

from repro.core.evaluator import evaluate_classifier
from repro.data.federated_datasets import (
    make_femnist_synthetic,
    make_lr_synthetic,
    make_reddit_synthetic,
)
from repro.data.partition import dirichlet_partition, shard_partition
from repro.federated.aggregation import fedavg, fedavg_delta
from repro.federated.selection import availability_aware_selection, random_selection
from repro.federated.server import FLConfig, FLServer
from repro.heterogeneity.availability import markov_trace
from repro.heterogeneity.profiles import (
    HETEROGENEITY_PROFILES,
    sample_client_systems,
)
from repro.models.small import make_lr


def test_fedavg_weighted_mean():
    t1 = {"w": np.ones((2, 2), np.float32)}
    t2 = {"w": np.full((2, 2), 3.0, np.float32)}
    avg = fedavg([t1, t2], [1.0, 3.0])
    np.testing.assert_allclose(np.asarray(avg["w"]), 2.5)


def test_fedavg_delta_matches_direct():
    rng = np.random.RandomState(0)
    g = {"w": rng.randn(3).astype(np.float32)}
    locals_ = [{"w": rng.randn(3).astype(np.float32)} for _ in range(3)]
    w = [1.0, 2.0, 1.0]
    direct = fedavg(locals_, w)
    via_delta = fedavg_delta(g, locals_, w)
    np.testing.assert_allclose(np.asarray(direct["w"]), np.asarray(via_delta["w"]),
                               rtol=1e-5, atol=1e-6)


def test_selection():
    rng = np.random.default_rng(0)
    ids = [f"c{i}" for i in range(100)]
    sel = random_selection(ids, 10, rng)
    assert len(sel) == 10 and len(set(sel)) == 10
    scores = {c: (1.0 if i % 3 == 0 else 1e-9) for i, c in enumerate(ids)}
    sel = availability_aware_selection(ids, 10, rng, scores)
    assert sum(1 for c in sel if scores[c] == 1.0) >= 9  # strongly prefers available


def test_markov_trace_stationarity():
    tr = markov_trace(num_clients=500, horizon=100, seed=0)
    assert 0.2 < tr.mean_availability < 0.8
    on = markov_trace(num_clients=10, horizon=10, always_on=True)
    assert on.mean_availability == 1.0


@pytest.mark.parametrize("profile,speed_spread,always_on", [
    ("U", False, True),
    ("DH", True, True),
    ("BH", False, False),
    ("H", True, False),
])
def test_heterogeneity_profiles(profile, speed_spread, always_on):
    systems, trace = sample_client_systems(
        200, HETEROGENEITY_PROFILES[profile], seed=0, horizon=50
    )
    times = [s.round_time(local_steps=10, model_mb=5.0) for s in systems]
    if speed_spread:
        assert max(times) / min(times) > 2.0
    else:
        assert max(times) / min(times) < 1.01
    assert (trace.mean_availability == 1.0) == always_on


def test_dirichlet_partition_noniid():
    labels = np.random.RandomState(0).randint(0, 10, size=2000)
    parts_iid = dirichlet_partition(labels, num_clients=20, alpha=100.0, seed=0)
    parts_noniid = dirichlet_partition(labels, num_clients=20, alpha=0.05, seed=0)
    assert sum(len(p) for p in parts_iid.values()) == 2000
    assert sum(len(p) for p in parts_noniid.values()) == 2000

    def mean_entropy(parts):
        es = []
        for p in parts.values():
            if len(p) == 0:
                continue
            c = np.bincount(labels[p], minlength=10) / len(p)
            es.append(-(c[c > 0] * np.log(c[c > 0])).sum())
        return np.mean(es)

    assert mean_entropy(parts_noniid) < mean_entropy(parts_iid) - 0.5


def test_shard_partition_covers_all():
    labels = np.random.RandomState(1).randint(0, 5, size=1000)
    parts = shard_partition(labels, num_clients=10, shards_per_client=2, seed=0)
    allidx = np.concatenate(list(parts.values()))
    assert len(allidx) == 1000 and len(np.unique(allidx)) == 1000


def test_fl_rounds_improve():
    ds = make_lr_synthetic(num_clients=20, seed=0)
    model = make_lr(num_features=ds.num_features, num_classes=ds.num_classes)
    server = FLServer(model, ds, FLConfig(rounds=8, clients_per_round=5,
                                          local_epochs=1, lr=0.1, seed=0))
    params0 = model.init(jax.random.PRNGKey(0))
    x, y = ds.merged_test(max_per_client=20)
    acc0 = evaluate_classifier(model.apply, params0, x, y,
                               num_classes=ds.num_classes)["accuracy"]
    params = server.run(params0)
    acc1 = evaluate_classifier(model.apply, params, x, y,
                               num_classes=ds.num_classes)["accuracy"]
    assert acc1 > acc0, (acc0, acc1)
    assert len(server.history) == 8
    assert all(r.survived <= r.selected for r in server.history)


def test_fl_heterogeneous_profile_drops_clients():
    ds = make_lr_synthetic(num_clients=30, seed=1)
    model = make_lr(num_features=ds.num_features, num_classes=ds.num_classes)
    server = FLServer(model, ds, FLConfig(rounds=6, clients_per_round=10,
                                          local_epochs=1, lr=0.1, seed=1,
                                          profile="H", round_deadline=30.0))
    server.run(model.init(jax.random.PRNGKey(0)))
    total_sel = sum(r.selected for r in server.history)
    total_sur = sum(r.survived for r in server.history)
    assert total_sur < total_sel  # stragglers/dropouts happened


def test_datasets_shapes():
    for fn, kw in [
        (make_lr_synthetic, dict(num_clients=10)),
        (make_femnist_synthetic, dict(num_clients=10)),
        (make_reddit_synthetic, dict(num_clients=10)),
    ]:
        ds = fn(seed=0, **kw)
        assert len(ds.client_ids()) == 10
        c = ds.clients[ds.client_ids()[0]]
        assert len(c.x_train) == len(c.y_train) > 0
        x, y = ds.merged_test(max_per_client=5)
        assert len(x) == len(y) > 0
