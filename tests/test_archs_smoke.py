"""Per-architecture smoke tests: reduced configs (<=2-4 layers, d_model<=512,
<=4 experts) run one forward + one train step + one decode step on CPU and
assert output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.launch.steps import make_serve_step, make_train_step
from repro.models import build_model
from repro.models.config import INPUT_SHAPES, ShapeConfig

# minutes of per-arch compilation on CPU; excluded from the fast tier-1 loop
pytestmark = pytest.mark.slow


def _batch(cfg, B, S, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.num_patches:
        batch["patches"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model), jnp.bfloat16
        )
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.num_frames, cfg.d_model), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_limits(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 4
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    assert cfg.family == get_config(arch).family


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_finite(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, S = 2, 32
    batch = _batch(cfg, B, S, key)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert np.isfinite(float(aux["moe_aux"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    cfg = get_smoke_config(arch)
    shape = ShapeConfig("t", 16, 4, "train", microbatches=2)
    step, model, opt = make_train_step(cfg, shape)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    opt_state = opt.init(params)
    batch = _batch(cfg, shape.global_batch, shape.seq_len, key)
    new_params, new_opt, metrics = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    before = jax.tree_util.tree_leaves(params)[1]
    after = jax.tree_util.tree_leaves(new_params)[1]
    assert not np.allclose(np.asarray(before, np.float32), np.asarray(after, np.float32))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """Greedy decode after prefill matches teacher-forced forward logits."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    B, S = 2, 16
    batch = _batch(cfg, B, S, key)

    logits_all, _ = jax.jit(model.forward)(params, batch)
    last, _, cache = jax.jit(model.prefill)(params, batch)
    np.testing.assert_allclose(
        np.asarray(last[:, -1], np.float32),
        np.asarray(logits_all[:, -1], np.float32),
        rtol=2e-2, atol=2e-2,
    )
    tok = {"token": jnp.argmax(last[:, -1], -1).astype(jnp.int32)[:, None]}
    logits2, cache2 = jax.jit(model.decode)(params, cache, tok)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))
    assert int(cache2["pos"]) == S + 1


@pytest.mark.parametrize("arch", ["qwen2_1_5b", "zamba2_2_7b", "xlstm_1_3b"])
def test_serve_step(arch):
    cfg = get_smoke_config(arch)
    step, model = make_serve_step(cfg)
    params = model.init(jax.random.PRNGKey(3))
    cache = model.init_cache(2, 16)
    tok, logits, cache = jax.jit(step)(
        params, cache, {"token": jnp.ones((2, 1), jnp.int32)}
    )
    assert tok.shape == (2,)
    assert int(cache["pos"]) == 1


def test_assigned_configs_exact():
    """The full configs match the assignment table exactly."""
    expect = {
        "nemotron_4_15b": (32, 6144, 48, 8, 24576, 256000),
        "deepseek_coder_33b": (62, 7168, 56, 8, 19200, 32256),
        "zamba2_2_7b": (54, 2560, 32, 32, 10240, 32000),
        "qwen3_moe_235b_a22b": (94, 4096, 64, 4, 1536, 151936),
        "chameleon_34b": (48, 8192, 64, 8, 22016, 65536),
        "llama4_scout_17b_a16e": (48, 5120, 40, 8, 8192, 202048),
        "whisper_base": (6, 512, 8, 8, 2048, 51865),
        "qwen2_1_5b": (28, 1536, 12, 2, 8960, 151936),
        "xlstm_1_3b": (48, 2048, 4, 4, 0, 50304),
        "minitron_4b": (32, 3072, 24, 8, 9216, 256000),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == (L, d, h, kv, ff, v), (arch, got)
    assert get_config("qwen3_moe_235b_a22b").num_experts == 128
    assert get_config("qwen3_moe_235b_a22b").experts_per_token == 8
    assert get_config("llama4_scout_17b_a16e").num_experts == 16
    assert get_config("llama4_scout_17b_a16e").experts_per_token == 1
    assert get_config("zamba2_2_7b").ssm_state == 64
    assert get_config("qwen2_1_5b").qkv_bias
    assert get_config("nemotron_4_15b").mlp_type == "squared_relu"


def test_input_shapes_table():
    t = INPUT_SHAPES
    assert (t["train_4k"].seq_len, t["train_4k"].global_batch) == (4096, 256)
    assert (t["prefill_32k"].seq_len, t["prefill_32k"].global_batch) == (32768, 32)
    assert (t["decode_32k"].seq_len, t["decode_32k"].global_batch) == (32768, 128)
    assert (t["long_500k"].seq_len, t["long_500k"].global_batch) == (524288, 1)
